"""Detection head + detection metrics, no model in the loop.

Synthetic posterior traces with known event placements → EXACT expected
smoothing values, hysteresis/refractory behaviour, and FA-per-hour /
miss-rate numbers (the satellite contract: the metrics themselves are
verified arithmetic, not eyeballed output).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import detector as det


def _scan(cfg, posts, batch=1):
    state = det.init_detector_state(batch, posts.shape[-1])
    state, events = det.detector_scan(cfg, state, jnp.asarray(posts))
    return state, np.asarray(events)


def _pulse(n_frames, k_classes, cls, start, end, level, base=None):
    """Posterior trace: uniform elsewhere, `level` on `cls` in [start,end]."""
    posts = np.full((n_frames, 1, k_classes),
                    (1.0 / k_classes) if base is None else base, np.float32)
    posts[start:end + 1, 0, :] = (1.0 - level) / (k_classes - 1)
    posts[start:end + 1, 0, cls] = level
    return posts


# ------------------------------------------------------------- smoothing --

def test_ema_smoothing_is_exact():
    cfg = det.DetectorConfig(smooth_alpha=0.5, fire_threshold=2.0)
    posts = np.zeros((4, 1, 3), np.float32)
    posts[:, 0, 2] = [1.0, 0.0, 1.0, 1.0]
    state, events = _scan(cfg, posts)
    # s_t = s_{t-1} + 0.5 (p_t - s_{t-1}), s_0 = 0:
    # 0.5, 0.25, 0.625, 0.8125
    np.testing.assert_allclose(float(state.smooth[0, 2]), 0.8125, rtol=1e-6)
    assert (events == det.NO_EVENT).all()     # threshold 2.0: never fires


def test_smooth_alpha_one_is_identity():
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=2.0)
    posts = np.random.default_rng(0).uniform(0, 1, (5, 2, 4)) \
        .astype(np.float32)
    state, _ = _scan(cfg, posts, batch=2)
    np.testing.assert_allclose(np.asarray(state.smooth), posts[-1],
                               rtol=1e-6)


# ------------------------------------------------- hysteresis state machine --

def test_fire_on_rising_edge_only_once_per_event():
    # alpha=1: score IS the posterior.  One sustained pulse above the
    # fire threshold must fire exactly once, on its first frame.
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=0.5,
                             release_threshold=0.3, refractory_frames=0,
                             first_keyword=2)
    posts = _pulse(20, 4, cls=3, start=5, end=12, level=0.9)
    state, events = _scan(cfg, posts)
    fired = np.flatnonzero(events[:, 0] != det.NO_EVENT)
    assert fired.tolist() == [5]
    assert events[5, 0] == 3
    assert int(state.active[0]) == det.NO_EVENT   # released after the pulse


def test_hysteresis_band_suppresses_rebounce():
    # Score path: 0.6 (fire) → 0.45 (inside band: stays latched) → 0.6
    # (still latched, NO new fire) → 0.2 (release) → 0.6 (fires again).
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=0.5,
                             release_threshold=0.3, refractory_frames=0,
                             first_keyword=2)
    levels = [0.6, 0.45, 0.6, 0.2, 0.6]
    posts = np.zeros((5, 1, 4), np.float32)
    for t, lv in enumerate(levels):
        posts[t, 0, 3] = lv
        posts[t, 0, 0] = 1.0 - lv
    _, events = _scan(cfg, posts)
    assert np.flatnonzero(events[:, 0] != det.NO_EVENT).tolist() == [0, 4]


def test_refractory_blocks_immediate_refire():
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=0.5,
                             release_threshold=0.3, refractory_frames=6,
                             first_keyword=2)
    # Two one-frame pulses 4 frames apart: the second is inside the
    # refractory window and must NOT fire; a third, 8 frames after the
    # first, fires.
    posts = np.zeros((12, 1, 4), np.float32)
    posts[:, 0, 0] = 1.0
    for t in (0, 4, 8):
        posts[t, 0, 3] = 0.9
        posts[t, 0, 0] = 0.1
    _, events = _scan(cfg, posts)
    assert np.flatnonzero(events[:, 0] != det.NO_EVENT).tolist() == [0, 8]


def test_non_keyword_classes_never_fire():
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=0.5,
                             first_keyword=2)
    posts = np.zeros((6, 1, 4), np.float32)
    posts[:, 0, 0] = 0.95                      # "silence" dominates
    posts[:, 0, 1] = 0.05
    _, events = _scan(cfg, posts)
    assert (events == det.NO_EVENT).all()


def test_detector_chunk_split_invariance():
    cfg = det.DetectorConfig()                 # defaults incl. smoothing
    rng = np.random.default_rng(1)
    posts = rng.dirichlet(np.ones(12) * 0.3, size=(40, 3)) \
        .astype(np.float32)
    s_full = det.init_detector_state(3, 12)
    s_full, ev_full = det.detector_scan(cfg, s_full, jnp.asarray(posts))
    s = det.init_detector_state(3, 12)
    parts = []
    for lo, hi in [(0, 7), (7, 8), (8, 29), (29, 40)]:
        s, ev = det.detector_scan(cfg, s, jnp.asarray(posts[lo:hi]))
        parts.append(np.asarray(ev))
    np.testing.assert_array_equal(np.concatenate(parts),
                                  np.asarray(ev_full))
    for a, b in zip(s, s_full):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_per_slot_independence():
    # Slot 0 sees a pulse, slot 1 silence: only slot 0 fires, and slot
    # 1's state is exactly the all-silence state.
    cfg = det.DetectorConfig(smooth_alpha=1.0, fire_threshold=0.5)
    posts = np.zeros((8, 2, 4), np.float32)
    posts[:, :, 0] = 1.0
    posts[3, 0, 3] = 0.9
    posts[3, 0, 0] = 0.1
    _, events = _scan(cfg, posts, batch=2)
    assert events[3, 0] == 3
    assert (events[:, 1] == det.NO_EVENT).all()


# ----------------------------------------------------------------- metrics --

HOUR_FRAMES = int(round(3600 / det.FRAME_S))          # 225000


def test_det_point_exact_fa_per_hour_and_miss_rate():
    truth = [(100, 130, 3), (1000, 1040, 5), (2000, 2030, 7)]
    fires = [(110, 3),        # hit
             (1500, 5),       # outside any window → FA
             (2010, 5)]       # inside event 3's window, wrong label → FA
    p = det.det_point(fires, truth, n_frames=HOUR_FRAMES)
    assert (p.n_events, p.hits, p.misses, p.false_alarms) == (3, 1, 2, 2)
    assert p.miss_rate == pytest.approx(2 / 3)
    assert p.fa_per_hour == pytest.approx(2.0)        # exactly 1 hour scored
    assert p.hours == pytest.approx(1.0)


def test_duplicate_fire_on_claimed_event_is_false_alarm():
    truth = [(100, 130, 3)]
    fires = [(110, 3), (120, 3)]
    hits, fas = det.match_fires(fires, truth)
    assert (hits, fas) == (1, 1)


def test_exact_span_match_preferred_over_tolerance_window():
    # Same-class events A then B close enough that tolerance windows
    # overlap; two fires INSIDE B must score B-hit + FA for the second
    # fire (A stays a miss) — not claim A by window spillover.
    truth = [(100, 120, 3), (140, 160, 3)]
    fires = [(145, 3), (155, 3)]
    assert det.match_fires(fires, truth, tol_frames=31) == (1, 1)
    p = det.det_point(fires, truth, n_frames=HOUR_FRAMES, tol_frames=31)
    assert (p.hits, p.misses, p.false_alarms) == (1, 1, 1)


def test_tolerance_window_extends_matching():
    truth = [(100, 130, 3)]
    assert det.match_fires([(140, 3)], truth, tol_frames=0) == (0, 1)
    assert det.match_fires([(140, 3)], truth, tol_frames=10) == (1, 0)
    assert det.match_fires([(95, 3)], truth, tol_frames=10) == (1, 0)


def test_no_events_no_fires_is_clean_zero():
    p = det.det_point([], [], n_frames=HOUR_FRAMES)
    assert p.miss_rate == 0.0 and p.fa_per_hour == 0.0


def test_fires_from_events_offsets():
    ev = np.full(10, det.NO_EVENT, np.int32)
    ev[4] = 6
    assert det.fires_from_events(ev) == [(4, 6)]
    assert det.fires_from_events(ev, frame_offset=100) == [(104, 6)]


def test_pool_points_recomputes_rates_from_counts():
    a = det.det_point([(10, 3)], [(5, 20, 3)], n_frames=HOUR_FRAMES)
    b = det.det_point([(50, 4)], [(100, 120, 5)], n_frames=HOUR_FRAMES)
    pooled = det.pool_points([a, b])
    assert (pooled.n_events, pooled.hits, pooled.false_alarms) == (2, 1, 1)
    assert pooled.miss_rate == pytest.approx(0.5)
    assert pooled.fa_per_hour == pytest.approx(0.5)   # 1 FA over 2 hours


# ------------------------------------- per-keyword thresholds (ISSUE 10) --

def test_tuple_thresholds_bitwise_equal_to_scalar():
    """fire/release as uniform tuples must reproduce the scalar config
    event-for-event (the conformance suites rely on this equivalence)."""
    rng = np.random.default_rng(0)
    posts = rng.uniform(0.0, 1.0, (400, 2, 5)).astype(np.float32)
    posts /= posts.sum(-1, keepdims=True)
    scalar = det.DetectorConfig(fire_threshold=0.30, release_threshold=0.2)
    tup = scalar._replace(fire_threshold=(0.30,) * 3,
                          release_threshold=(0.2,) * 3)
    _, ev_s = _scan(scalar, posts, batch=2)
    _, ev_t = _scan(tup, posts, batch=2)
    np.testing.assert_array_equal(ev_s, ev_t)


def test_per_keyword_fire_thresholds_select_independently():
    """Class 2 needs > 0.6 while class 3 needs only > 0.3: a frame with
    (0.5, 0.35) fires class 3, not class 2."""
    cfg = det.DetectorConfig(smooth_alpha=1.0, refractory_frames=0,
                             fire_threshold=(0.6, 0.3),
                             release_threshold=(0.1, 0.1))
    posts = np.full((3, 1, 4), 0.05, np.float32)
    posts[1, 0, 2] = 0.5          # below ITS threshold
    posts[1, 0, 3] = 0.35         # above its own
    _, events = _scan(cfg, posts)
    assert events[1, 0] == 3
    # Swap the tuple: now the same frame fires class 2 instead.
    cfg2 = cfg._replace(fire_threshold=(0.3, 0.6))
    _, events2 = _scan(cfg2, posts)
    assert events2[1, 0] == 2


def test_per_keyword_release_holds_event_open():
    """The event closes only when EVERY keyword drops below its own
    release level."""
    cfg = det.DetectorConfig(smooth_alpha=1.0, refractory_frames=0,
                             fire_threshold=(0.5, 0.5),
                             release_threshold=(0.4, 0.1))
    posts = np.zeros((4, 1, 4), np.float32)
    posts[0, 0, 2] = 0.6          # fire class 2
    posts[1, 0, 3] = 0.2          # class 3 still above ITS release? no:
    posts[2, 0, 3] = 0.2          # 0.2 > 0.1 keeps the latch closed^Wopen
    _, events = _scan(cfg, posts)
    assert events[0, 0] == 2
    state = det.init_detector_state(1, 4)
    state, _ = det.detector_scan(cfg, state, jnp.asarray(posts[:3]))
    assert int(state.active[0]) == 2      # 0.2 > release[1]=0.1: open
    state2 = det.init_detector_state(1, 4)
    state2, _ = det.detector_scan(
        cfg._replace(release_threshold=(0.4, 0.3)), state2,
        jnp.asarray(posts[:3]))
    assert int(state2.active[0]) == det.NO_EVENT   # 0.2 < 0.3: released


def test_band_inverted_per_keyword():
    ok = det.DetectorConfig(fire_threshold=(0.6, 0.4),
                            release_threshold=(0.5, 0.3))
    assert not det.band_inverted(ok)
    bad = ok._replace(release_threshold=(0.5, 0.45))  # one class inverted
    assert det.band_inverted(bad)
    with pytest.raises(ValueError, match="equal lengths"):
        det.band_inverted(ok._replace(release_threshold=(0.1, 0.1, 0.1)))


def test_streaming_session_rejects_per_keyword_inverted_band():
    import jax
    from repro.configs import get_config
    from repro.launch.streaming import StreamingKwsSession
    import repro.models.kws as kws
    cfg = get_config("deltakws")
    params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg, input_dim=16)
    bad = det.DetectorConfig(fire_threshold=(0.5,) * 10,
                             release_threshold=(0.4,) * 9 + (0.6,))
    with pytest.raises(ValueError, match="inverted hysteresis"):
        StreamingKwsSession(params, cfg, threshold=0.0, batch=1,
                            detector=bad)


# --------------------------------------------------- per-cell calibration --

def _calib_posts():
    """(F=30000, K=4) trace: class 2 events are strong (0.9), class 3
    events weak (0.5) with three 0.7-level false-alarm bumps."""
    posts = np.full((30000, 4), 0.01, np.float32)
    truth = []
    for s in (1000, 5000):                       # class-2 events
        posts[s:s + 41, 2] = 0.9
        truth.append((s, s + 40, 2))
    for s in (9000, 13000):                      # class-3 events
        posts[s:s + 41, 3] = 0.5
        truth.append((s, s + 40, 3))
    for s in (20000, 22000, 24000):              # class-3 FA bumps
        posts[s:s + 11, 3] = 0.7
    return posts, sorted(truth)


def test_calibration_picks_per_class_operating_points():
    posts, truth = _calib_posts()
    base = det.DetectorConfig(smooth_alpha=1.0, first_keyword=2)
    ths = det.calibrate_fire_thresholds(
        posts, truth, base, candidates=(0.35, 0.8),
        fa_budget_per_hour=10.0)                 # 0.133 h ⇒ ≤ 1 FA
    # class 2: both candidates are FA-free and hit both events → the
    # most permissive wins; class 3: 0.35 trips all three bumps (22.5
    # FA/hr, over budget) → forced up to 0.8 despite the misses.
    assert ths == (0.35, 0.8)


def test_calibration_falls_back_to_strictest_when_budget_unreachable():
    posts, truth = _calib_posts()
    posts[:, 3] = 0.95                           # class 3 fires always
    base = det.DetectorConfig(smooth_alpha=1.0, first_keyword=2)
    ths = det.calibrate_fire_thresholds(
        posts, truth, base, candidates=(0.3, 0.5),
        fa_budget_per_hour=0.5)
    assert ths[1] == 0.5                         # strictest candidate
    with pytest.raises(ValueError, match="candidates"):
        det.calibrate_fire_thresholds(posts, truth, base, candidates=())


def test_calibrated_tuple_round_trips_through_detector_scan():
    posts, truth = _calib_posts()
    base = det.DetectorConfig(smooth_alpha=1.0, first_keyword=2)
    ths = det.calibrate_fire_thresholds(posts, truth, base,
                                        candidates=(0.35, 0.8),
                                        fa_budget_per_hour=10.0)
    cfg = base._replace(fire_threshold=ths,
                        release_threshold=tuple(0.75 * t for t in ths))
    _, events = _scan(cfg, posts[:, None, :])
    fires = det.fires_from_events(events)
    hits, fas = det.match_fires(fires, truth, tol_frames=4)
    assert hits >= 2                             # both class-2 events
    assert fas == 0                              # bumps under 0.8 gate
