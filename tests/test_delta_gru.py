"""Core ΔGRU behaviour: exactness at Δ_TH=0, sparsity properties, training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (delta_encode, delta_gru_scan, dense_gru_scan,
                        init_delta_gru, temporal_sparsity)

KEY = jax.random.PRNGKey(0)


def _setup(T=24, B=3, I=10, H=16, seed=0):
    p = init_delta_gru(jax.random.PRNGKey(seed), I, H)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (T, B, I))
    return p, xs


def test_threshold_zero_equals_dense_gru():
    p, xs = _setup()
    hs_d, _, stats = delta_gru_scan(p, xs, threshold=0.0)
    hs_ref = dense_gru_scan(p, xs)
    np.testing.assert_allclose(np.asarray(hs_d), np.asarray(hs_ref),
                               rtol=3e-5, atol=3e-5)


def test_sparsity_zero_at_zero_threshold_is_low():
    p, xs = _setup()
    _, _, stats = delta_gru_scan(p, xs, threshold=0.0)
    # only exact-zero deltas skip at th=0 (h=0 initial states)
    assert float(temporal_sparsity(stats)) < 0.2


@settings(max_examples=10, deadline=None)
@given(th1=st.floats(0.0, 0.5), th2=st.floats(0.0, 0.5))
def test_sparsity_monotone_in_threshold(th1, th2):
    lo, hi = sorted([th1, th2])
    p, xs = _setup(T=12, B=2)
    _, _, s_lo = delta_gru_scan(p, xs, threshold=lo)
    _, _, s_hi = delta_gru_scan(p, xs, threshold=hi)
    assert float(temporal_sparsity(s_hi)) >= float(temporal_sparsity(s_lo)) - 1e-6


@settings(max_examples=20, deadline=None)
@given(th=st.floats(0.0, 1.0))
def test_delta_encode_invariants(th):
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8))
    x_hat = jax.random.normal(jax.random.PRNGKey(3), (4, 8))
    d, new_hat, mask = delta_encode(x, x_hat, th)
    # transmitted components: delta exact, memory updated to x
    np.testing.assert_allclose(np.where(mask, d, 0), np.where(mask, x - x_hat, 0),
                               rtol=1e-6)
    np.testing.assert_allclose(np.where(mask, new_hat, 0), np.where(mask, x, 0),
                               rtol=1e-6)
    # silent components: zero delta, memory unchanged
    assert np.all(np.where(mask, 0, d) == 0)
    np.testing.assert_array_equal(np.asarray(jnp.where(mask, 0, new_hat)),
                                  np.asarray(jnp.where(mask, 0, x_hat)))
    # sub-threshold deviations bounded: |x - x̂_new| ≤ th where not transmitted
    assert float(jnp.max(jnp.abs(jnp.where(mask, 0, x - new_hat)))) <= th + 1e-6


def test_accumulator_consistency():
    """M_t must equal W_x x̂_t + W_h ĥ_t at every step (the IC invariant)."""
    from repro.core.delta_gru import DeltaGRUCell, init_delta_state
    p, xs = _setup(T=10, B=2)
    cell = jax.jit(lambda s, x: DeltaGRUCell(16, 0.3)(p, s, x))
    s = init_delta_state(2, 10, 16, p)
    for t in range(10):
        s, h, _ = cell(s, xs[t])
        m_expect = s.x_hat @ p.w_x + p.b
        np.testing.assert_allclose(np.asarray(s.m_x), np.asarray(m_expect),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s.m_h),
                                   np.asarray(s.h_hat @ p.w_h),
                                   rtol=2e-4, atol=2e-4)


def test_bounded_divergence_from_dense():
    """Hidden state deviation stays bounded (delta networks' key property)."""
    p, xs = _setup(T=40, B=2)
    hs_ref = dense_gru_scan(p, xs)
    for th in [0.05, 0.1, 0.2]:
        hs, _, _ = delta_gru_scan(p, xs, threshold=th)
        dev = float(jnp.max(jnp.abs(hs - hs_ref)))
        assert dev < 12 * th, (th, dev)


def test_gradients_flow():
    p, xs = _setup()

    def loss(params):
        hs, _, _ = delta_gru_scan(params, xs, threshold=0.1)
        return jnp.sum(hs ** 2)

    g = jax.grad(loss)(p)
    assert all(np.all(np.isfinite(np.asarray(x))) for x in jax.tree.leaves(g))
    assert float(jnp.max(jnp.abs(g.w_x))) > 0
