"""Autotune subsystem tests: cache robustness, dispatch pickup, knob
validation, platform resolution — and the property that makes tuning safe
at all: every tuned config is bit-identical to the default config, in
both float and integer numerics (DESIGN.md §12)."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta_gru as dg
from repro.core import fixed_point as fp
from repro.frontend import fex as fx
from repro.frontend.fex import FExConfig, build_sos_bank
from repro.kernels import autotune, platform
from repro.kernels.iir_fex import pack_coefficients


@pytest.fixture
def cache(tmp_path, monkeypatch):
    """Point the autotune cache at a fresh temp file, memo cleared."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.ENV_CACHE, str(path))
    monkeypatch.delenv(autotune.ENV_ENABLE, raising=False)
    autotune.clear_memo()
    yield path
    autotune.clear_memo()


# ------------------------------------------------------------ cache I/O
def test_missing_cache_falls_back_to_defaults(cache):
    assert not cache.exists()
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32", 0.2) \
        is None
    assert autotune.resolve("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                            B=8, T=100) == {}


def test_corrupt_cache_falls_back_without_error(cache):
    cache.write_text("{ this is not json !!!")
    autotune.clear_memo()
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32", 0.2) \
        is None
    # and a well-formed file with a garbage entries type
    cache.write_text(json.dumps({"schema": autotune.SCHEMA_VERSION,
                                 "entries": [1, 2, 3]}))
    autotune.clear_memo()
    assert autotune.resolve("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                            B=8, T=100) == {}


def test_stale_schema_falls_back(cache):
    key = autotune.cache_key("delta_gru_seq", (8, 64, 64), "float32", 0.2)
    cache.write_text(json.dumps({
        "schema": autotune.SCHEMA_VERSION + 1,
        "entries": {key: {"config": {"block_b": 2}}}}))
    autotune.clear_memo()
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32", 0.2) \
        is None


def test_record_then_hit_roundtrip(cache):
    key = autotune.record("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                          {"block_b": 4, "block_t": 2},
                          tuned_us=10.0, default_us=20.0)
    got = autotune.lookup("delta_gru_seq", (8, 64, 64), "float32", 0.2)
    assert got == {"block_b": 4, "block_t": 2}
    blob = json.loads(cache.read_text())
    assert blob["schema"] == autotune.SCHEMA_VERSION
    assert blob["entries"][key]["speedup"] == pytest.approx(2.0)
    # a second record for a different key must not clobber the first
    autotune.record("batched_iir_fex", (8, 10, 128), "float32", 0.0,
                    {"block_b": 8, "unroll": 4}, tuned_us=1.0,
                    default_us=2.0)
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32", 0.2) \
        == {"block_b": 4, "block_t": 2}


def test_key_separates_threshold_buckets_and_platform(cache):
    autotune.record("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                    {"block_t": 2}, tuned_us=1.0, default_us=2.0)
    # 0.21 rounds into the same 0.2 bucket; 0.5 does not
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32",
                           0.21) == {"block_t": 2}
    assert autotune.lookup("delta_gru_seq", (8, 64, 64), "float32",
                           0.5) is None
    k_int = autotune.cache_key("delta_gru_seq", (8, 64, 64), "float32",
                               0.2, interpret=True)
    k_cmp = autotune.cache_key("delta_gru_seq", (8, 64, 64), "float32",
                               0.2, interpret=False)
    assert k_int.endswith("-interpret") and k_cmp.endswith("-compiled")
    assert k_int != k_cmp


def test_resolve_sanitizes_illegal_knobs(cache):
    autotune.record("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                    {"block_b": 4, "block_t": 8}, tuned_us=1.0,
                    default_us=2.0)
    # block_t=8 does not divide T=30 -> dropped; block_b survives
    assert autotune.resolve("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                            B=8, T=30) == {"block_b": 4}
    # block_b=4 does not divide B=6 -> dropped
    assert autotune.resolve("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                            B=6, T=32) == {"block_t": 8}
    # float-FEx block_b=1 carve-out (1-ulp FMA wobble at B=1)
    autotune.record("batched_iir_fex", (8, 10, 128), "float32", 0.0,
                    {"block_b": 1, "unroll": 4}, tuned_us=1.0,
                    default_us=2.0)
    assert autotune.resolve("batched_iir_fex", (8, 10, 128), "float32",
                            0.0, B=8, frame_shift=128) == {"unroll": 4}


def test_env_disable_ignores_entries(cache, monkeypatch):
    autotune.record("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                    {"block_t": 2}, tuned_us=1.0, default_us=2.0)
    monkeypatch.setenv(autotune.ENV_ENABLE, "0")
    assert autotune.resolve("delta_gru_seq", (8, 64, 64), "float32", 0.2,
                            B=8, T=100) == {}


def test_threshold_bucket_handles_traced_values():
    assert autotune.threshold_bucket(0.27) == pytest.approx(0.3)
    assert autotune.threshold_bucket(5.0) == 1.0

    buckets = []

    @jax.jit
    def f(th):
        buckets.append(autotune.threshold_bucket(th))
        return th

    f(jnp.float32(0.4))
    assert buckets == [0.0]        # traced -> conservative 0.0 bucket


# ------------------------------------------------- tuned == default, bitwise
def test_tuned_config_bit_identical_float(cache):
    p = dg.init_delta_gru(jax.random.PRNGKey(0), 12, 16)
    xs = jax.random.normal(jax.random.PRNGKey(1), (20, 8, 12)) * 0.5
    base = dg.delta_gru_scan(p, xs, 0.2, backend="pallas")
    autotune.record("delta_gru_seq", (8, 12, 16), "float32", 0.2,
                    {"block_b": 2, "block_t": 5}, tuned_us=1.0,
                    default_us=2.0)
    tuned = dg.delta_gru_scan(p, xs, 0.2, backend="pallas")
    np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(tuned[0]))
    for a, b in zip(base[1], tuned[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuned_config_bit_identical_int(cache):
    p = dg.init_delta_gru(jax.random.PRNGKey(2), 12, 16)
    w, fmt = fp.quantize_gru(p)
    xs = jax.random.normal(jax.random.PRNGKey(3), (20, 8, 12)) * 0.5
    xc = fp.to_code(xs, fmt.feat_frac, 16, jnp.int16)
    golden = fp.int_gru_scan(w, fmt, xc, 0.2, backend="xla")
    base = fp.int_gru_scan(w, fmt, xc, 0.2, backend="pallas")
    autotune.record("delta_gru_seq_int", (8, 12, 16), "int8", 0.2,
                    {"block_b": 4, "block_t": 4}, tuned_us=1.0,
                    default_us=2.0)
    tuned = fp.int_gru_scan(w, fmt, xc, 0.2, backend="pallas")
    for ref in (golden, base):
        np.testing.assert_array_equal(np.asarray(ref[0]),
                                      np.asarray(tuned[0]))
        for a, b in zip(ref[1], tuned[1]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuned_config_bit_identical_fex(cache):
    coef = pack_coefficients(build_sos_bank(FExConfig()))
    audio = jax.random.normal(jax.random.PRNGKey(4), (8, 4096)) * 0.1
    base_f, base_s = fx.fex_scan(audio, coef, backend="pallas")
    autotune.record("batched_iir_fex", (8, 10, 128), "float32", 0.0,
                    {"block_b": 4, "unroll": 8}, tuned_us=1.0,
                    default_us=2.0)
    tuned_f, tuned_s = fx.fex_scan(audio, coef, backend="pallas")
    np.testing.assert_array_equal(np.asarray(base_f), np.asarray(tuned_f))
    for a, b in zip(base_s, tuned_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tuned_config_bit_identical_fex_int(cache):
    coef = pack_coefficients(build_sos_bank(FExConfig()))
    audio = jax.random.normal(jax.random.PRNGKey(5), (8, 4096)) * 0.1
    base_f, base_s = fx.fex_scan(audio, coef, backend="pallas-int")
    autotune.record("batched_iir_fex_int", (8, 10, 128), "int16", 0.0,
                    {"block_b": 2, "unroll": 16}, tuned_us=1.0,
                    default_us=2.0)
    tuned_f, tuned_s = fx.fex_scan(audio, coef, backend="pallas-int")
    np.testing.assert_array_equal(np.asarray(base_f), np.asarray(tuned_f))
    for a, b in zip(base_s, tuned_s):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------ the tuner
def test_tune_writes_winner_consulted_by_dispatch(cache):
    report = autotune.tune_delta_gru_seq(T=10, B=4, I=8, H=8,
                                         threshold=0.2, iters=1)
    assert report["cache_key"] in json.loads(cache.read_text())["entries"]
    assert report["best_us"] <= report["default_us"]
    got = autotune.resolve("delta_gru_seq", (4, 8, 8), "float32", 0.2,
                           B=4, T=10)
    assert got == {k: v for k, v in report["best_config"].items()
                   if k in got}
    # sweep covered both axes beyond the default
    assert len(report["sweep"]) >= 3


def test_tune_fex_writes_winner(cache):
    report = autotune.tune_batched_iir_fex(B=4, seconds=0.1, iters=1)
    entries = json.loads(cache.read_text())["entries"]
    assert report["cache_key"] in entries
    # float FEx never records block_b=1 (excluded candidate)
    assert report["best_config"].get("block_b") != 1


def test_session_kernel_tuning_report(cache):
    from repro.configs import get_config
    from repro.launch.streaming import StreamingKwsSession
    from repro.models import kws

    cfg = get_config("deltakws")
    fex_cfg = FExConfig()
    params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg,
                             input_dim=fex_cfg.n_active)
    autotune.record("delta_gru_seq", (2, fex_cfg.n_active, cfg.d_model),
                    "float32", 0.2, {"block_t": 2}, tuned_us=1.0,
                    default_us=2.0)
    sess = StreamingKwsSession(params, cfg, threshold=0.2, batch=2,
                               input_dim=fex_cfg.n_active, fex=fex_cfg)
    report = sess.kernel_tuning_report()
    assert report["cache"] == str(cache)
    assert report["kernels"]["delta_gru_seq"]["config"] == {"block_t": 2}
    assert report["kernels"]["batched_iir_fex"]["config"] == {}  # cold


# ------------------------------------------------------- knob validation
def test_block_b_validation_messages():
    with pytest.raises(ValueError, match=r"delta_gru_seq.*block_b=3.*B=8"):
        autotune.validate_block_b("delta_gru_seq", 8, 3)
    with pytest.raises(ValueError, match="batched_iir_fex"):
        autotune.validate_block_b("batched_iir_fex", 8, 0)
    assert autotune.validate_block_b("k", 8, None) == 8
    assert autotune.validate_block_b("k", 8, 4) == 4


def test_validate_divisor_messages():
    with pytest.raises(ValueError, match=r"unroll=7.*frame_shift=128"):
        autotune.validate_divisor("batched_iir_fex", "unroll", 7,
                                  "frame_shift", 128)
    assert autotune.validate_divisor("k", "block_t", None, "T", 100) == 1
    assert autotune.validate_divisor("k", "block_t", 25, "T", 100) == 25


# ------------------------------------------------------------- platform
def test_gpu_backend_selects_compiled_lowering(monkeypatch):
    monkeypatch.delenv(platform._ENV_VAR, raising=False)
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    assert platform.default_interpret() is False       # Triton, not interpret
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert platform.default_interpret() is False       # Mosaic
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert platform.default_interpret() is True


def test_env_override_beats_detection(monkeypatch):
    monkeypatch.setattr(jax, "default_backend", lambda: "gpu")
    monkeypatch.setenv(platform._ENV_VAR, "1")
    assert platform.default_interpret() is True
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    monkeypatch.setenv(platform._ENV_VAR, "0")
    assert platform.default_interpret() is False


def test_resolution_logged_once(monkeypatch, caplog):
    monkeypatch.delenv(platform._ENV_VAR, raising=False)
    monkeypatch.setattr(platform, "_logged_decision", None)
    with caplog.at_level("INFO", logger="repro.kernels.platform"):
        platform.default_interpret()
        platform.default_interpret()
        platform.default_interpret()
    msgs = [r for r in caplog.records
            if "pallas execution mode" in r.message]
    assert len(msgs) == 1
    assert "platform=" in msgs[0].message


# ------------------------------------------------- concurrent writers
_RACER = r"""
import os, sys
sys.path.insert(0, {src!r})
from repro.kernels import autotune
tag = int(sys.argv[1])
for i in range(30):
    autotune.record("delta_gru_seq", (8, 64, 64), "float32", 0.1 * tag,
                    {{"block_b": 8, "block_h": 16}},
                    tuned_us=10.0 + i, default_us=20.0)
print("done", tag)
"""


def test_concurrent_writers_never_corrupt_cache(cache, tmp_path):
    """Two PROCESSES hammering ``record`` against one cache file: the
    per-writer tmp + atomic-rename protocol means the worst case is a
    lost update (last writer wins), NEVER a torn/corrupt file — the
    final cache parses, and lookups succeed without raising."""
    import pathlib
    import subprocess
    import sys

    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    script = tmp_path / "racer.py"
    script.write_text(_RACER.format(src=src))
    env = dict(os.environ, REPRO_AUTOTUNE_CACHE=str(cache))
    procs = [subprocess.Popen([sys.executable, str(script), str(tag)],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE)
             for tag in (1, 2)]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()
        assert b"done" in out

    # The file is a complete, schema-correct blob from ONE writer.
    blob = json.loads(cache.read_text())
    assert blob["schema"] == autotune.SCHEMA_VERSION
    assert isinstance(blob["entries"], dict) and blob["entries"]
    # No leftover staging files.
    assert not list(cache.parent.glob("*.tmp"))
    # Lookup never raises, and the surviving writer's entry is served.
    autotune.clear_memo()
    hits = [autotune.lookup("delta_gru_seq", (8, 64, 64), "float32",
                            0.1 * tag) for tag in (1, 2)]
    assert any(h == {"block_b": 8, "block_h": 16} for h in hits)
