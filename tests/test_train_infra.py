"""Training substrate: optimizer, checkpoint, fault tolerance, compression,
sharding rules, data determinism."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import compress
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                          total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 1.0])
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state, m = opt.update(cfg, g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clip_bounds_update():
    cfg = opt.AdamWConfig(lr=1.0, grad_clip=1e-8, weight_decay=0.0)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    g = {"w": jnp.full(4, 1e6)}
    new, state, m = opt.update(cfg, g, state, params)
    assert float(m["grad_norm"]) > 1e5
    np.testing.assert_allclose(np.asarray(new["w"]), 1.0, atol=1e-2)


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6                 # warmup peak
    assert abs(lrs[-1] - 0.1) < 1e-3                # cosine floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones(4, jnp.bfloat16)},
            "t": (jnp.zeros(2), jnp.ones(3))}
    ckpt.save(tmp_path, 7, tree)
    assert ckpt.latest_step(tmp_path) == 7
    back = ckpt.restore(tmp_path, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_torn_write_ignored(tmp_path):
    tree = {"a": jnp.ones(3)}
    ckpt.save(tmp_path, 5, tree)
    # simulate a crashed writer: stale .tmp dir + incomplete final dir
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000011").mkdir()            # no meta.json
    assert ckpt.latest_step(tmp_path) == 5


def test_trainer_fault_recovery(tmp_path):
    """A step that raises is retried from the last checkpoint; the final
    state equals an uninterrupted run (replayable data → exactness)."""
    cfg = opt.AdamWConfig(lr=0.05, weight_decay=0.0)

    def make(ckpt_dir):
        params = {"w": jnp.zeros(2)}
        state = opt.init(params)

        def step_fn(p, s, batch):
            g = jax.grad(lambda q: jnp.sum((q["w"] - batch) ** 2))(p)
            p2, s2, m = opt.update(cfg, g, s, p)
            return p2, s2, {"loss": jnp.sum((p["w"] - batch) ** 2)}

        data = lambda step: jnp.asarray([1.0, -1.0]) * (1 + 0.01 * step)
        return Trainer(TrainerConfig(ckpt_dir=str(ckpt_dir), ckpt_every=5,
                                     max_retries=3), step_fn,
                       params, state, data)

    t_ref = make(tmp_path / "ref")
    t_ref.run(20)

    boom = {"armed": True}

    def fault_hook(step):
        if step == 12 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    t_ft = make(tmp_path / "ft")
    t_ft.run(20, fault_hook=fault_hook)
    assert t_ft.recoveries == 1
    np.testing.assert_allclose(np.asarray(t_ft.params["w"]),
                               np.asarray(t_ref.params["w"]), rtol=1e-6)


def test_trainer_straggler_detection(tmp_path):
    import time
    params = {"w": jnp.zeros(1)}
    state = opt.init(params)

    def step_fn(p, s, batch):
        if int(batch) == 7:
            time.sleep(0.25)
        return p, s, {"loss": jnp.zeros(())}

    tr = Trainer(TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                               straggler_factor=3.0),
                 step_fn, params, state, lambda s: s)
    tr.run(12)
    assert 7 in tr.straggler_steps


# --------------------------------------------------------------- compression
def test_compression_error_feedback_unbiased():
    """Over many steps, sum(sent) ≈ sum(grads): residual stays bounded."""
    state = compress.init_state({"w": jnp.zeros((32, 32))})
    rng = np.random.default_rng(0)
    total_g = np.zeros((32, 32))
    total_sent = np.zeros((32, 32))
    for i in range(30):
        g = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
        sent, state, m = compress.compress(g, state, frac=0.05)
        total_g += np.asarray(g["w"])
        total_sent += np.asarray(sent["w"])
    resid = np.asarray(state.residual["w"])
    np.testing.assert_allclose(total_sent + resid, total_g, rtol=1e-4,
                               atol=1e-4)
    assert float(m["density"]) <= 0.08


def test_compression_density_matches_frac():
    state = compress.init_state({"w": jnp.zeros(1000)})
    g = {"w": jax.random.normal(KEY, (1000,))}
    sent, state, m = compress.compress(g, state, frac=0.01)
    nnz = int(jnp.sum(sent["w"] != 0))
    assert nnz <= 15                            # ~1% of 1000 (ties allowed)


# ----------------------------------------------------------------- sharding
def test_sharder_no_mesh_identity():
    from repro.parallel.sharding import Sharder
    shd = Sharder(mesh=None)
    x = jnp.ones((4, 4))
    assert shd.act(x, ("batch", "seq")) is x


def test_sharder_divisibility_fallback():
    from repro.parallel.sharding import Sharder
    import jax.sharding as js

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 2))
    shd = Sharder.__new__(Sharder)
    shd.mesh = FakeMesh()
    shd.rules = dict(__import__("repro.parallel.sharding",
                                fromlist=["DEFAULT_RULES"]).DEFAULT_RULES)
    shd._axis_sizes = {"data": 4, "model": 2}
    # divisible: sharded; non-divisible: replicated
    spec = shd.spec((8, 6), ("batch", "mlp"))
    assert spec == js.PartitionSpec("data", "model")
    spec = shd.spec((7, 5), ("batch", "mlp"))
    assert spec == js.PartitionSpec(None, None)
    # no axis reuse within one tensor
    spec = shd.spec((8, 8), ("batch", "kv_seq"))
    assert spec == js.PartitionSpec("data", "model")


# --------------------------------------------------------------------- data
def test_lm_data_replayable():
    from repro.data.lm_data import SyntheticLM
    d1 = SyntheticLM(vocab_size=128, seq_len=16, batch=4, seed=3)
    d2 = SyntheticLM(vocab_size=128, seq_len=16, batch=4, seed=3)
    b1, b2 = d1.batch_at(11), d2.batch_at(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d1.batch_at(12)["tokens"], b1["tokens"])


def test_synth_commands_classes_distinguishable():
    from repro.data.gscd import synth_batch
    from repro.frontend import FeatureExtractor
    rng = np.random.default_rng(0)
    audio, labels = synth_batch(rng, 48)
    fex = FeatureExtractor()
    feats = np.asarray(fex(jnp.asarray(audio)))
    assert feats.shape[0] == 48 and np.all(np.isfinite(feats))
    # silence class has visibly lower energy than keywords
    sil = feats[labels == 0].mean() if np.any(labels == 0) else None
    kw = feats[labels >= 2].mean()
    if sil is not None:
        assert sil < kw
