import os
# Keep the real device count for tests (dry-run sets its own 512 in its own
# process). Cap compilation parallelism for the 1-CPU container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
