"""Property tests for ``core.quantize`` — the formats every integer
surface (golden model, kernels, promotion) is built on.

Runs under hypothesis when installed; degrades to the deterministic
sample grid of ``tests/_hypothesis_compat.py`` in a bare container.
"""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.quantize import (QFormat, quantize_audio_12b,
                                 quantize_weights_8b, ste_quantize)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 3), st.integers(1, 14))
def test_to_int_from_int_roundtrip(int_bits, frac_bits):
    """from_int ∘ to_int == quantize for in-range values, and
    to_int ∘ from_int is the identity on every representable code."""
    fmt = QFormat(int_bits, frac_bits)
    rng = np.random.default_rng(int_bits * 31 + frac_bits)
    x = rng.uniform(fmt.min_val, fmt.max_val, 128)
    np.testing.assert_allclose(fmt.from_int(fmt.to_int(x)),
                               fmt.quantize(x), rtol=0, atol=0)
    codes = np.arange(-(2 ** (fmt.total_bits - 1)),
                      2 ** (fmt.total_bits - 1))
    np.testing.assert_array_equal(fmt.to_int(fmt.from_int(codes)), codes)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 3), st.integers(1, 14))
def test_saturation_at_min_and_max(int_bits, frac_bits):
    fmt = QFormat(int_bits, frac_bits)
    big = np.array([1e12, fmt.max_val + 1.0, fmt.max_val + fmt.step])
    np.testing.assert_array_equal(fmt.quantize(big),
                                  np.full(3, fmt.max_val))
    small = np.array([-1e12, fmt.min_val - 1.0, fmt.min_val - fmt.step])
    np.testing.assert_array_equal(fmt.quantize(small),
                                  np.full(3, fmt.min_val))
    # integer codes saturate at the word limits, consistently with the
    # value-domain clip
    assert int(fmt.to_int(np.array([1e12]))[0]) == \
        2 ** (fmt.total_bits - 1) - 1
    assert int(fmt.to_int(np.array([-1e12]))[0]) == \
        -(2 ** (fmt.total_bits - 1))
    # min_val/max_val themselves are exactly representable fixed points
    np.testing.assert_array_equal(
        fmt.quantize(np.array([fmt.min_val, fmt.max_val])),
        np.array([fmt.min_val, fmt.max_val]))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2), st.integers(2, 12))
def test_ste_gradient_is_identity(int_bits, frac_bits):
    """The straight-through estimator quantizes forward but passes the
    cotangent through unchanged — including where quantize saturates
    (the STE contract QAT training relies on)."""
    fmt = QFormat(int_bits, frac_bits)
    x = jnp.asarray(np.linspace(fmt.min_val - 1.0, fmt.max_val + 1.0, 64),
                    jnp.float32)
    y, vjp = jax.vjp(lambda v: ste_quantize(v, fmt), x)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(fmt.quantize(x)))
    ct = jnp.asarray(np.random.default_rng(0).normal(size=64), jnp.float32)
    (grad,) = vjp(ct)
    np.testing.assert_array_equal(np.asarray(grad), np.asarray(ct))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 14))
def test_quantize_idempotent_on_grid(frac_bits):
    fmt = QFormat(0, frac_bits)
    rng = np.random.default_rng(frac_bits)
    q = fmt.quantize(rng.uniform(fmt.min_val, fmt.max_val, 256))
    np.testing.assert_array_equal(fmt.quantize(q), q)


def test_audio_12b_is_on_grid_and_saturates():
    x = jnp.asarray([-2.0, -1.0, 0.0, 0.3, 1.0, 2.0], jnp.float32)
    q = np.asarray(quantize_audio_12b(x))
    fmt = QFormat(0, 11)
    assert q.min() >= fmt.min_val and q.max() <= fmt.max_val
    steps = q / fmt.step
    np.testing.assert_allclose(steps, np.round(steps), atol=1e-6)


def test_weight_quantization_scale_is_power_of_two():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(0, 0.4, (16, 8)), jnp.float32)
    wq, scale = quantize_weights_8b(w)
    assert float(np.log2(scale)) == int(np.log2(scale))
    codes = np.asarray(wq) / (scale * 2.0 ** -7)
    np.testing.assert_allclose(codes, np.round(codes), atol=1e-5)
    assert np.abs(codes).max() <= 128          # Q0.7: [-1, 1 − 2⁻⁷]
