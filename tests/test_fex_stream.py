"""Batched sequence-resident FEx kernel + audio-in streaming tests.

Three contracts (ISSUE 2 acceptance):
  * the Pallas FEx kernel is FLOAT-EXACT against the XLA ``lax.scan``
    reference (single-source per-sample math, same op order);
  * both are correct against the ``filters.sosfilt_np`` float64 oracle;
  * chunk boundaries — frame-aligned or not — are bit-invisible, at the
    ``fex_scan`` level and through the fused audio→decision session.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.frontend import filters
from repro.frontend.fex import (FExConfig, FeatureExtractor, build_sos_bank,
                                fex_scan, init_fex_state)
from repro.kernels.iir_fex import batched_iir_fex, pack_coefficients
from repro.kernels.ops import init_fex_kernel_state

KEY = jax.random.PRNGKey(0)
CFG = FExConfig()
COEF = pack_coefficients(build_sos_bank(CFG))


def _audio(B, T, seed=0, scale=0.5):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-scale, scale, (B, T)), jnp.float32)


# ------------------------------------------------------- oracle correctness
def test_kernel_matches_sosfilt_oracle():
    """env_alpha=1 turns the envelope LP into |y|, so frame outputs are
    exactly the rectified float64 DF2T cascade, decimated."""
    bank = build_sos_bank(CFG)
    x = np.asarray(_audio(1, 2048)[0], np.float64)
    feats, _ = batched_iir_fex(
        jnp.asarray(x, jnp.float32)[None], COEF,
        init_fex_kernel_state(1, CFG.n_active), frame_shift=128,
        env_alpha=1.0, compress=False)
    got = np.asarray(feats[0])                       # (16, C)
    for ch in range(CFG.n_active):
        y = filters.sosfilt_np(bank[ch], x)
        want = np.abs(y)[127::128]
        np.testing.assert_allclose(got[:, ch], want, rtol=2e-4, atol=2e-5)


# ------------------------------------------- pallas vs xla scan float-exact
@pytest.mark.parametrize("compress", [True, False])
@pytest.mark.parametrize("B,T,block_b", [(1, 1024, None), (4, 2048, None),
                                         (8, 1024, 2)])
def test_pallas_float_exact_vs_xla_scan(B, T, block_b, compress):
    audio = _audio(B, T, seed=B + T)
    state = init_fex_state(B, CFG.n_active)
    fx, sx = fex_scan(audio, COEF, state, env_alpha=CFG.env_alpha,
                      compress=compress, backend="xla")
    fp, sp = fex_scan(audio, COEF, state, env_alpha=CFG.env_alpha,
                      compress=compress, backend="pallas", block_b=block_b)
    np.testing.assert_array_equal(np.asarray(fx), np.asarray(fp))
    np.testing.assert_array_equal(np.asarray(sx.filt), np.asarray(sp.filt))
    np.testing.assert_array_equal(np.asarray(sx.env), np.asarray(sp.env))


@pytest.mark.parametrize("unroll", [2, 4, 8, 16, 128])
@pytest.mark.parametrize("block_b", [None, 2])
def test_unroll_and_tiling_bit_identical(unroll, block_b):
    """The per-sample loop unroll and the double-buffered state prefetch
    must be invisible: identical ops in identical order, so any legal
    (block_b, unroll) equals the default bit for bit — features AND
    carried state (the DMA pipeline seeds exactly the tile's carry)."""
    audio = _audio(8, 2048, seed=21)
    state = init_fex_state(8, CFG.n_active)
    # non-trivial initial state so the prefetch path is actually exercised
    f0, s0 = fex_scan(audio, COEF, state, env_alpha=CFG.env_alpha,
                      backend="pallas")
    f1, s1 = fex_scan(audio[:, :1024], COEF, s0, env_alpha=CFG.env_alpha,
                      backend="pallas")
    f2, s2 = fex_scan(audio[:, :1024], COEF, s0, env_alpha=CFG.env_alpha,
                      backend="pallas", block_b=block_b, unroll=unroll)
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    np.testing.assert_array_equal(np.asarray(s1.filt), np.asarray(s2.filt))
    np.testing.assert_array_equal(np.asarray(s1.env), np.asarray(s2.env))


def test_fex_bad_tiles_raise_named_valueerror():
    with pytest.raises(ValueError,
                       match=r"batched_iir_fex: block_b=5 .*B=8"):
        fex_scan(_audio(8, 512), COEF, backend="pallas", block_b=5)
    with pytest.raises(ValueError,
                       match=r"batched_iir_fex: unroll=7 .*frame_shift=128"):
        fex_scan(_audio(8, 512), COEF, backend="pallas", unroll=7)
    with pytest.raises(ValueError, match=r"batched_iir_fex_int: unroll=9"):
        fex_scan(_audio(4, 512), COEF, backend="pallas-int", unroll=9)


def test_fex_backend_rejects_unknown():
    with pytest.raises(ValueError):
        fex_scan(_audio(1, 256), COEF, backend="cuda")


# ------------------------------------------------- chunk-boundary carrying
@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_fex_scan_state_carry_bit_invisible(backend):
    """[a | b] through two calls with the state carried == one call on the
    concatenation, bit for bit (frame-aligned split: the kernel consumes
    whole frames; sample-level remainders are the session's job)."""
    audio = _audio(3, 2048, seed=7)
    kw = dict(env_alpha=CFG.env_alpha, backend=backend)
    once, _ = fex_scan(audio, COEF, **kw)
    f1, s1 = fex_scan(audio[:, :768], COEF, **kw)
    f2, _ = fex_scan(audio[:, 768:], COEF, s1, **kw)
    np.testing.assert_array_equal(
        np.asarray(jnp.concatenate([f1, f2], axis=1)), np.asarray(once))


def test_feature_extractor_call_matches_scan_and_is_12bit():
    fex = FeatureExtractor()
    audio = _audio(2, 4000, seed=3)
    feats = fex(audio)
    assert feats.shape == (2, 31, 10)
    steps = np.asarray(feats) / 2.0 ** -11
    np.testing.assert_allclose(steps, np.round(steps), atol=1e-3)
    feats_p = fex(audio, backend="pallas")
    np.testing.assert_array_equal(np.asarray(feats), np.asarray(feats_p))


# ------------------------------------------------------ audio-in sessions
class TestAudioInSession:
    def _session(self, batch=1, **kw):
        from repro.configs import get_config
        from repro.launch.streaming import StreamingKwsSession
        from repro.models import kws
        cfg = get_config("deltakws")
        params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg, input_dim=10)
        fex = FeatureExtractor()
        sess = StreamingKwsSession(params, cfg, threshold=0.1, batch=batch,
                                   fex=fex, **kw)
        return cfg, params, fex, sess

    def test_unaligned_chunks_equal_oneshot(self):
        """Audio split at NON-frame-aligned offsets (the remainder carry)
        must be bit-invisible in the logits."""
        cfg, params, fex, sess = self._session()
        audio = np.asarray(_audio(1, 8000, seed=11)[0])
        outs = [sess.process_audio(audio[a:b])
                for a, b in [(0, 3000), (3000, 5005), (5005, 8000)]]
        chunked = jnp.concatenate(
            [o.logits for o in outs if o.logits.shape[0]], axis=0)
        _, _, _, sess2 = self._session()
        once = sess2.process_audio(audio).logits
        np.testing.assert_array_equal(np.asarray(chunked), np.asarray(once))

    def test_audio_path_equals_feature_path(self):
        """Feeding raw audio must produce the same per-frame logits as
        pre-computing features and feeding them (same weights/state)."""
        cfg, params, fex, sess = self._session()
        audio = np.asarray(_audio(1, 4096, seed=13)[0])
        lg_audio = sess.process_audio(audio).logits
        from repro.launch.streaming import StreamingKwsSession
        sess_f = StreamingKwsSession(params, cfg, threshold=0.1)
        feats = fex(jnp.asarray(audio[None]))[0]       # (F, C)
        lg_feats = sess_f.process_chunk(feats).logits
        np.testing.assert_array_equal(np.asarray(lg_audio),
                                      np.asarray(lg_feats))

    def test_short_chunk_buffers_without_frames(self):
        cfg, params, fex, sess = self._session()
        out = sess.process_audio(np.zeros(100, np.float32))   # < one frame
        assert out.logits.shape[0] == 0
        out = sess.process_audio(np.zeros(100, np.float32))
        assert out.logits.shape[0] == 1                       # 200 // 128
        assert sess.summary().frames == 1

    def test_batched_streams_fex_telemetry(self):
        cfg, params, fex, sess = self._session(batch=3)
        audio = np.asarray(_audio(3, 2048, seed=17))
        out = sess.process_audio(audio)
        assert out.votes.shape == (16, 3)
        s = sess.summary()
        # decisions (and samples) count across all 3 streams
        assert s.frames == 16 * 3 and s.fex_samples == 16 * 3 * 128
        assert s.fex_energy_nj_per_decision > 0.0

    def test_reset_stream_isolates_one_slot(self):
        """Resetting slot 0 re-zeroes exactly that stream: replaying its
        audio reproduces its fresh-stream logits while slot 1 diverges
        from a fresh stream (it kept its state)."""
        cfg, params, fex, sess = self._session(batch=2)
        audio = np.asarray(_audio(2, 2048, seed=19))
        first = sess.process_audio(audio).logits
        sess.reset_stream(0)
        again = sess.process_audio(audio).logits
        np.testing.assert_array_equal(np.asarray(again[:, 0]),
                                      np.asarray(first[:, 0]))
        assert not np.array_equal(np.asarray(again[:, 1]),
                                  np.asarray(first[:, 1]))

    @pytest.mark.parametrize("numerics,seed", [("float32", 0),
                                               ("float32", 1),
                                               ("int8", 0), ("int8", 1)])
    def test_chunk_split_fuzz_bit_identical(self, numerics, seed):
        """Streaming chunk-invariance fuzz: RANDOM chunk splits —
        including 1-sample chunks — through ``process_audio`` produce
        bit-identical decisions to the one-shot call, in both float and
        int8 numerics (the remainder-carry + state-carry contract)."""
        from repro.configs import get_config
        from repro.launch.streaming import StreamingKwsSession
        from repro.models import kws
        cfg = get_config("deltakws")
        params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg, input_dim=10)
        rng = np.random.default_rng(seed)
        T = 1200
        audio = rng.uniform(-0.8, 0.8, T).astype(np.float32)
        # random cut points; force a 1-sample chunk into every split
        cuts = np.sort(rng.choice(np.arange(1, T), size=4, replace=False))
        one = int(rng.integers(1, T - 1))
        cuts = np.unique(np.concatenate([cuts, [one, one + 1]]))
        bounds = [0, *cuts.tolist(), T]

        def session():
            return StreamingKwsSession(params, cfg, threshold=0.1,
                                       fex=FeatureExtractor(),
                                       numerics=numerics)

        once = session().process_audio(audio)
        sess = session()
        outs = [sess.process_audio(audio[a:b])
                for a, b in zip(bounds, bounds[1:])]
        chunked_lg = jnp.concatenate(
            [o.logits for o in outs if o.logits.shape[0]], axis=0)
        chunked_votes = jnp.concatenate(
            [o.votes for o in outs if o.votes.shape[0]], axis=0)
        np.testing.assert_array_equal(np.asarray(chunked_lg),
                                      np.asarray(once.logits))
        np.testing.assert_array_equal(np.asarray(chunked_votes),
                                      np.asarray(once.votes))

    def test_forward_audio_matches_offline_pipeline(self):
        from repro.models import kws
        cfg, params, fex, _ = self._session()
        audio = _audio(2, 4096, seed=23)
        lg_a, st_a = kws.forward_audio(params, cfg, audio, fex,
                                       threshold=0.1)
        feats = fex(audio)
        lg_f, st_f = kws.forward(params, cfg, feats, threshold=0.1)
        np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_f))
        np.testing.assert_array_equal(np.asarray(st_a.macs),
                                      np.asarray(st_f.macs))
