"""End-to-end driver: train the paper's KWS model with the production
Trainer — checkpointing, fault injection + recovery, straggler watchdog —
then PROMOTE it to the deployed integer numerics.

Training runs QAT by default (8-bit STE weights, Q0.15 hidden grid —
``models.kws.loss_fn(qat=True)``), so the final fold into the int8
bundle (``core.fixed_point.promote_kws``) serves within a hair of the
float accuracy; the script prints both.

Run:  PYTHONPATH=src python examples/train_kws_e2e.py [--steps 200]
"""
import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.gscd import synth_batch
from repro.frontend import FeatureExtractor
from repro.models import kws
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--inject-fault", action="store_true", default=True)
    ap.add_argument("--no-qat", action="store_true",
                    help="train in pure float (skips deployment numerics)")
    args = ap.parse_args()

    cfg = get_config("deltakws")
    fex = FeatureExtractor()
    params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    opt_state = opt.init(params)
    qat = not args.no_qat
    # The canonical QAT step (single-sourced with launch.train's KWS mode).
    from repro.train.promote import eval_promotion, make_kws_step_fn
    step_fn = make_kws_step_fn(cfg, ocfg, 0.1, qat=qat)

    def data_fn(step):               # replayable: pure function of step
        audio, labels = synth_batch(np.random.default_rng(step), 64)
        return {"feats": fex(jnp.asarray(audio)),
                "labels": jnp.asarray(labels)}

    ckpt_dir = tempfile.mkdtemp(prefix="deltakws_ckpt_")
    trainer = Trainer(TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=25),
                      step_fn, params, opt_state, data_fn)

    fault = {"armed": args.inject_fault}

    def fault_hook(step):
        if step == args.steps // 2 and fault["armed"]:
            fault["armed"] = False
            print(f"  !! injected node failure at step {step} — recovering "
                  f"from checkpoint")
            raise RuntimeError("simulated preemption")

    hist = trainer.run(args.steps, fault_hook=fault_hook)
    for h in hist[:: max(1, len(hist) // 10)]:
        print(f"  step {h.step:4d}  loss {h.metrics['loss']:.3f} "
              f"acc {h.metrics['acc']:.3f} "
              f"sparsity {h.metrics.get('sparsity', 0):.3f} "
              f"{'STRAGGLER' if h.is_straggler else ''}")
    print(f"recoveries: {trainer.recoveries}, "
          f"stragglers flagged: {len(trainer.straggler_steps)}")
    print(f"final acc: {hist[-1].metrics['acc']:.3f}")

    # Train→deploy promotion: fold into the integer bundle and compare
    # the float path against the bit-true int8 pipeline on held-out data.
    acc_f, acc_i, _ = eval_promotion(trainer.params, cfg, fex, 0.1)
    print(f"promotion ({'QAT' if qat else 'float'}-trained): "
          f"float acc {acc_f:.3f} → int8 acc {acc_i:.3f} "
          f"(Δ {acc_i - acc_f:+.3f})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
