"""Framework example: train a reduced LM from the architecture zoo on the
synthetic token stream, with checkpoint/restore.

Run:  PYTHONPATH=src python examples/lm_train.py --arch qwen2-0.5b --steps 60
Any of the 10 assigned architectures works (reduced config).
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.configs.base import ShapeConfig
from repro.data.lm_data import SyntheticLM
from repro.launch.steps import build_train_step
from repro.models import get_api
from repro.parallel.sharding import Sharder
from repro.train import optimizer as opt
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    shape = ShapeConfig("example", seq_len=args.seq,
                        global_batch=args.batch, kind="train")
    shd = Sharder(mesh=None)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps)
    fn, _ = build_train_step(cfg, shape, shd, opt_cfg=ocfg)

    api = get_api(cfg, shd)
    params, _ = api.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def data_fn(step):
        batch = {k: jax.numpy.asarray(v)
                 for k, v in data.batch_at(step).items()}
        if cfg.frontend != "none":
            batch["embeds"] = jax.numpy.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model),
                jax.numpy.float32)
        return batch

    ckpt = tempfile.mkdtemp(prefix=f"lm_{args.arch}_")
    trainer = Trainer(TrainerConfig(ckpt_dir=ckpt, ckpt_every=25),
                      fn, params, state, data_fn)
    hist = trainer.run(args.steps)
    losses = [h.metrics["loss"] for h in hist]
    print(f"{args.arch}: loss {losses[0]:.3f} → {losses[-1]:.3f} over "
          f"{args.steps} steps (ckpts in {ckpt})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
