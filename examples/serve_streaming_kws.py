"""Serving example: streaming always-on KWS with ZERO per-frame host syncs.

The IC's deployment mode is one decision per 16 ms frame with all ΔRNN
state resident on-chip.  This example mirrors that with a
``StreamingKwsSession``: audio arrives in chunks, each chunk is ONE fused
sequence-resident Pallas kernel launch (``kernels.delta_gru_seq`` —
weights + x̂/ĥ/M state stay in VMEM across all frames of the chunk), the
ΔGRU state carries across chunk boundaries on device, and op-count
telemetry accumulates on device.  The host fetches device results once
per chunk and the energy/sparsity summary once at the end — no
``float()``/``int()`` per frame forcing a device sync every 16 ms.

Run:  PYTHONPATH=src python examples/serve_streaming_kws.py
"""
import pathlib
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))  # benchmarks/
from benchmarks.common import train_kws
from repro.core.energy_model import frame_cost
from repro.data.gscd import _SPECS, _synth_keyword, _synth_silence, _synth_unknown
from repro.launch.streaming import StreamingKwsSession
from repro.models.kws import CLASSES

CHUNK = 31          # frames per chunk (~0.5 s of audio at 16 ms/frame)


def main():
    print("training detector ...")
    cfg, params, fex, _, _ = train_kws(n_steps=200)

    # a 4-second stream: yes → silence → stop → unknown
    rng = np.random.default_rng(5)
    segs, truth = [], []
    for name in ["yes", "silence", "stop", "unknown"]:
        if name == "silence":
            segs.append(_synth_silence(rng))
        elif name == "unknown":
            segs.append(_synth_unknown(rng))
        else:
            segs.append(_synth_keyword(rng, _SPECS[name]))
        truth.append(name)
    stream = np.concatenate(segs)

    feats = fex(jnp.asarray(stream[None]))[0]        # (frames, C)
    frames_per_seg = len(feats) // len(truth)

    sess = StreamingKwsSession(params, cfg, threshold=0.1,
                               input_dim=feats.shape[1])
    n_chunks = -(-len(feats) // CHUNK)
    print(f"\nstreaming {len(feats)} frames in {n_chunks} chunks of {CHUNK} "
          f"(one fused ΔGRU pallas_call per chunk, state carried on device):")
    for c in range(n_chunks):
        lo = c * CHUNK
        chunk = feats[lo:lo + CHUNK]
        out = sess.process_chunk(chunk)              # device arrays, no sync
        # ONE host fetch per chunk: frame votes + per-frame transmit counts.
        votes, nz = np.asarray(out.votes[:, 0]), np.asarray(out.nz[:, 0])
        mid = lo + len(chunk) // 2
        seg = min(mid // frames_per_seg, len(truth) - 1)
        top = np.bincount(votes, minlength=len(CLASSES)).argmax()
        macs_pf = nz.mean() * 3 * cfg.d_model
        print(f"  chunk {c} frames {lo:3d}-{lo + len(chunk) - 1:3d} "
              f"[truth={truth[seg]:8s}] vote={CLASSES[top]:8s} "
              f"avg_macs/frame={macs_pf:6.0f} "
              f"energy={frame_cost(macs_pf).energy_nj_per_decision:6.1f}nJ")

    s = sess.summary()                               # ONE telemetry fetch
    print(f"\nstream sparsity: {s.sparsity:.3f}  "
          f"avg energy {s.energy_nj_per_decision:.1f} nJ/decision  "
          f"avg latency {s.latency_ms:.2f} ms "
          f"(dense would be {s.dense_energy_nj:.1f} nJ)")


if __name__ == "__main__":
    main()
