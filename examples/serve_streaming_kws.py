"""Serving example: streaming always-on KWS — frame-by-frame ΔGRU decode
with live temporal-sparsity and energy telemetry (the IC's deployment
mode: one decision per 16 ms frame).

Uses the fused Pallas cell (interpret mode on CPU) for the per-frame step,
demonstrating kernels as the serving hot path.

Run:  PYTHONPATH=src python examples/serve_streaming_kws.py
"""
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))  # benchmarks/
from benchmarks.common import train_kws
from repro.core.energy_model import frame_cost
from repro.data.gscd import synth_batch
from repro.kernels import ops
from repro.models import kws
from repro.models.kws import CLASSES


def main():
    print("training detector ...")
    cfg, params, fex, _, _ = train_kws(n_steps=200)
    gru = kws._gru_params(params, False)
    th = 0.1

    # a 4-second stream: yes → silence → stop → unknown
    rng = np.random.default_rng(5)
    segs, truth = [], []
    for name in ["yes", "silence", "stop", "unknown"]:
        audio, labels = synth_batch(rng, 1)
        from repro.data.gscd import (_SPECS, _synth_keyword, _synth_silence,
                                     _synth_unknown)
        if name == "silence":
            segs.append(_synth_silence(rng))
        elif name == "unknown":
            segs.append(_synth_unknown(rng))
        else:
            segs.append(_synth_keyword(rng, _SPECS[name]))
        truth.append(name)
    stream = np.concatenate(segs)

    feats = fex(jnp.asarray(stream[None]))[0]        # (frames, C)
    B, I, H = 1, feats.shape[1], cfg.d_model
    x_hat = jnp.zeros((B, I))
    h = jnp.zeros((B, H))
    h_hat = jnp.zeros((B, H))
    m_x = jnp.broadcast_to(gru.b[None], (B, 3 * H))
    m_h = jnp.zeros((B, 3 * H))

    print(f"\nstreaming {len(feats)} frames "
          f"(16 ms each; fused ΔGRU Pallas cell):")
    total_macs = dense_macs = 0
    votes = []
    for f in range(len(feats)):
        x = feats[f][None]
        nz_before = (jnp.sum(jnp.abs(x - x_hat) > th)
                     + jnp.sum(jnp.abs(h - h_hat) > th))
        h, x_hat, h_hat, m_x, m_h = ops.delta_gru_cell(
            x, h, x_hat, h_hat, m_x, m_h, gru.w_x, gru.w_h, th)
        macs = float(nz_before) * 3 * H
        total_macs += macs
        dense_macs += (I + H) * 3 * H
        logits = h @ params["w_fc"] + params["b_fc"]
        votes.append(int(jnp.argmax(logits)))
        if f % 62 == 20:        # mid-utterance snapshot
            seg = min(f // 62, len(truth) - 1)
            c = frame_cost(macs)
            print(f"  frame {f:3d} [truth={truth[seg]:8s}] "
                  f"pred={CLASSES[votes[-1]]:8s} "
                  f"macs={macs:6.0f} energy={c.energy_nj_per_decision:6.1f}nJ")
    sparsity = 1 - total_macs / dense_macs
    c = frame_cost(total_macs / len(feats))
    print(f"\nstream sparsity: {sparsity:.3f}  "
          f"avg energy {c.energy_nj_per_decision:.1f} nJ/decision  "
          f"avg latency {c.latency_ms:.2f} ms "
          f"(dense would be {frame_cost(dense_macs/len(feats)).energy_nj_per_decision:.1f} nJ)")


if __name__ == "__main__":
    main()
