"""Serving example: always-on KWS from RAW AUDIO with ZERO per-frame syncs.

The IC's deployment mode is audio in, decisions out: 8 kHz samples enter
the FEx, one decision leaves per 16 ms frame, every register stays
on-chip.  This example mirrors that end to end with a
``StreamingKwsSession`` in audio mode: raw audio arrives in chunks, each
chunk is ONE fused jitted step — batched sequence-resident FEx
(``kernels.iir_fex``, biquad/envelope state VMEM-carried) feeding the
fused sequence-resident ΔGRU (``kernels.delta_gru_seq``) and the FC head
with no host hop between the stages.  FEx state, ΔGRU state and op-count
telemetry all carry across chunk boundaries on device; the host fetches
device results once per chunk and the energy/sparsity summary once at
the end.

Run with the exact command README.md documents (repro.commands is the
single source of truth for both):

    PYTHONPATH=src python examples/serve_streaming_kws.py
"""
import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).parent.parent))  # benchmarks/
from benchmarks.common import train_kws
from repro import commands
from repro.core.energy_model import frame_cost
from repro.data.gscd import _SPECS, _synth_keyword, _synth_silence, _synth_unknown
from repro.launch.streaming import StreamingKwsSession
from repro.models.kws import CLASSES

CHUNK = 4000        # raw samples per chunk (~0.5 s of 8 kHz audio)


def main():
    print("training detector ...")
    cfg, params, fex, _, _ = train_kws(n_steps=200)

    # a 4-second stream: yes → silence → stop → unknown
    rng = np.random.default_rng(5)
    segs, truth = [], []
    for name in ["yes", "silence", "stop", "unknown"]:
        if name == "silence":
            segs.append(_synth_silence(rng))
        elif name == "unknown":
            segs.append(_synth_unknown(rng))
        else:
            segs.append(_synth_keyword(rng, _SPECS[name]))
        truth.append(name)
    stream = np.concatenate(segs).astype(np.float32)
    samples_per_seg = len(stream) // len(truth)

    sess = StreamingKwsSession(params, cfg, threshold=0.1, fex=fex)
    n_chunks = -(-len(stream) // CHUNK)
    print(f"\nstreaming {len(stream)} raw samples in {n_chunks} chunks of "
          f"{CHUNK} (one fused FEx→ΔGRU→FC step per chunk, all state "
          f"carried on device):")
    frame0 = 0
    for c in range(n_chunks):
        lo = c * CHUNK
        out = sess.process_audio(stream[lo:lo + CHUNK])   # raw audio, no sync
        # ONE host fetch per chunk: frame votes + per-frame transmit counts.
        votes, nz = np.asarray(out.votes[:, 0]), np.asarray(out.nz[:, 0])
        if len(votes) == 0:
            continue
        mid = lo + CHUNK // 2
        seg = min(mid // samples_per_seg, len(truth) - 1)
        top = np.bincount(votes, minlength=len(CLASSES)).argmax()
        macs_pf = nz.mean() * 3 * cfg.d_model
        print(f"  chunk {c} frames {frame0:3d}-{frame0 + len(votes) - 1:3d} "
              f"[truth={truth[seg]:8s}] vote={CLASSES[top]:8s} "
              f"avg_macs/frame={macs_pf:6.0f} "
              f"energy={frame_cost(macs_pf).energy_nj_per_decision:6.1f}nJ")
        frame0 += len(votes)

    s = sess.summary()                               # ONE telemetry fetch
    print(f"\nstream sparsity: {s.sparsity:.3f}  "
          f"avg energy {s.energy_nj_per_decision:.1f} nJ/decision "
          f"(FEx share {s.fex_energy_nj_per_decision:.1f} nJ from "
          f"{s.fex_samples} counted samples)  "
          f"avg latency {s.latency_ms:.2f} ms "
          f"(dense would be {s.dense_energy_nj:.1f} nJ)")
    print("\nto serve MANY concurrent streams (commands as documented "
          "in README.md):")
    print(f"  one device:  {commands.SERVE_CMD}")
    print(f"  sharded:     {commands.SERVE_SHARDED_CMD}")


if __name__ == "__main__":
    main()
