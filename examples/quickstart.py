"""Quickstart: the paper's full pipeline in ~60 lines.

  audio (12-bit, 8 kHz) → IIR BPF FEx → ΔGRU(64) → FC(12)

Trains on SynthCommands (GSCD offline fallback), then shows the paper's
headline trade-off: accuracy / temporal sparsity / energy / latency vs
the delta threshold.

Run with the exact command README.md documents (repro.commands is the
single source of truth for both):

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import commands
from repro.configs import get_config
from repro.core import temporal_sparsity
from repro.core.energy_model import cost_from_sparsity
from repro.data.gscd import synth_batch
from repro.frontend import FeatureExtractor
from repro.models import kws
from repro.train import optimizer as opt

TRAIN_TH = 0.1      # threshold-aware training (DeltaRNN recipe)


def main():
    cfg = get_config("deltakws")
    fex = FeatureExtractor()
    params, _ = kws.init_kws(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=300)
    state = opt.init(params)
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, state, feats, labels):
        (loss, m), g = jax.value_and_grad(kws.loss_fn, has_aux=True)(
            params, cfg, {"feats": feats, "labels": labels}, TRAIN_TH)
        params, state, _ = opt.update(ocfg, g, state, params)
        return params, state, loss, m["acc"]

    print("training ΔGRU KWS on SynthCommands ...")
    for i in range(300):
        audio, labels = synth_batch(rng, 64)
        feats = fex(jnp.asarray(audio))
        params, state, loss, acc = step(params, state, feats,
                                        jnp.asarray(labels))
        if i % 50 == 0:
            print(f"  step {i:4d}  loss {float(loss):.3f}  "
                  f"acc {float(acc):.3f}")

    audio, labels = synth_batch(np.random.default_rng(99), 512)
    feats = fex(jnp.asarray(audio))
    labels = jnp.asarray(labels)
    print("\n Δ_TH   acc12  acc11  sparsity  nJ/decision  latency_ms")
    for th in [0.0, 0.05, 0.1, 0.2]:
        logits, stats = kws.forward(params, cfg, feats, threshold=th)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == labels))
        acc11 = float(kws.accuracy_11class(logits, labels))
        sp = float(temporal_sparsity(stats))
        c = cost_from_sparsity(sp)
        print(f"  {th:.2f}  {acc:6.3f} {acc11:6.3f}  {sp:8.3f}"
              f"  {c.energy_nj_per_decision:11.2f}  {c.latency_ms:10.2f}")
    print("\npaper design point: 87% sparsity → 36.11 nJ, 6.9 ms "
          "(3.4× / 2.4× vs dense)")
    print("\nnext steps (commands as documented in README.md):")
    print(f"  stream raw audio:   {commands.STREAM_EXAMPLE_CMD}")
    print(f"  serve a slot pool:  {commands.SERVE_CMD}")
    print(f"  shard the slots:    {commands.SERVE_SHARDED_CMD}")


if __name__ == "__main__":
    main()
